"""Reliability subsystem: failure-domain injection, checkpoint cadence,
goodput accounting — and the real-mechanism failure path in the executor.

The paper's reliability claim (§1, §6) is that a hardware failure is just
another preemption: the job loses only the work since its last snapshot
and restarts wherever capacity exists.  These tests pin the properties
the simulator's failure machinery must keep:

- seeded injection is bit-reproducible and traces replay from JSON;
- capacity is conserved across fail/repair (no dead GPU is ever handed
  out, and the fleet returns to full strength after repairs);
- the vectorized and scalar policy paths emit identical decision
  sequences under a failure storm (the equivalence gate holds with the
  reliability machinery engaged);
- realized goodput orders premium >= standard >= basic under storms;
- the Young–Daly cadence strictly improves fleet goodput versus
  checkpoint-on-preempt-only on the same storm.
"""
import hashlib

import numpy as np

from repro.scheduler.costs import CostModel
from repro.scheduler.executor import FleetExecutor, ManagedJob
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.reliability import (
    CheckpointCadence,
    FailureModel,
    FailureTrace,
)
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.types import Cluster, Fleet, Job, Region

HORIZON = 36 * 3600.0


def _storm_model(seed: int = 0) -> FailureModel:
    """MTBFs cranked so a 2048-GPU, 36 h trace sees a real storm."""
    return FailureModel(
        device_mtbf_seconds=20 * 24 * 3600.0,
        node_mtbf_seconds=30 * 24 * 3600.0,
        cluster_mtbf_seconds=60 * 24 * 3600.0,
        seed=seed,
    )


def _storm_trace(fleet) -> FailureTrace:
    return FailureTrace.merge(
        _storm_model().sample(fleet, HORIZON),
        FailureTrace.cluster_outage("r0c0", at=8 * 3600.0),
    )


def _storm_sim(
    vectorized_policy: bool = True, cadence=None, digest=False, job_table=True
):
    fleet = make_fleet()
    jobs = synth_workload(250, fleet.total(), seed=1234, mean_interarrival=120.0)
    policy = ElasticPolicy(vectorized=vectorized_policy)
    wrapper = _DigestPolicy(policy) if digest else policy
    sim = FleetSimulator(
        fleet,
        jobs,
        wrapper,
        SimConfig(
            horizon_seconds=HORIZON,
            cost_model=CostModel(),
            failures=_storm_trace(fleet),
            cadence=cadence,
            validate=True,  # capacity conservation asserted every decision
            job_table=job_table,
        ),
    )
    return sim, wrapper


class _DigestPolicy:
    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.digest = hashlib.sha256()

    def bind_costs(self, cost_model, interval_hint):
        self.inner.bind_costs(cost_model, interval_hint)

    def decide(self, now, jobs, fleet):
        decision = self.inner.decide(now, jobs, fleet)
        self.digest.update(
            repr(
                (
                    sorted(decision.alloc.items()),
                    decision.preemptions,
                    decision.migrations,
                )
            ).encode()
        )
        return decision


# ------------------------------------------------------------ sampling
def test_seeded_sampling_is_reproducible():
    fleet = make_fleet()
    a = _storm_model(seed=7).sample(fleet, HORIZON)
    b = _storm_model(seed=7).sample(fleet, HORIZON)
    assert len(a) > 20
    assert a == b
    assert _storm_model(seed=8).sample(fleet, HORIZON) != a


def test_trace_json_roundtrip(tmp_path):
    fleet = make_fleet()
    trace = FailureTrace.merge(
        _storm_model().sample(fleet, HORIZON),
        FailureTrace.region_drain("r1", at=10 * 3600.0),
    )
    path = str(tmp_path / "storm.json")
    trace.save(path)
    assert FailureTrace.load(path) == trace


def test_weibull_shape_changes_arrivals_not_determinism():
    fleet = make_fleet()
    exp = FailureModel(seed=3, cluster_mtbf_seconds=30 * 24 * 3600.0)
    wei = FailureModel(
        seed=3, cluster_mtbf_seconds=30 * 24 * 3600.0, weibull_shape=0.6
    )
    assert wei.sample(fleet, HORIZON) == wei.sample(fleet, HORIZON)
    assert wei.sample(fleet, HORIZON) != exp.sample(fleet, HORIZON)


def test_job_failure_rate_scales_with_span():
    m = _storm_model()
    assert m.job_failure_rate(256) > m.job_failure_rate(8) > 0
    rates = m.job_failure_rate(np.array([8.0, 64.0, 256.0]))
    assert rates.shape == (3,) and np.all(np.diff(rates) > 0)


# ---------------------------------------------------- fail/repair cycle
def test_capacity_conserved_across_failure_and_repair():
    """A cluster outage kills exactly that cluster's capacity, every
    running job there is force-preempted (progress rolled back to its
    snapshot), and repair restores the fleet to full strength — with the
    simulator's per-decision conservation asserts on throughout."""
    fleet = Fleet(
        [Region("r0", [Cluster("r0c0", "r0", 64), Cluster("r0c1", "r0", 64)])]
    )
    jobs = [
        Job(
            id=f"j{i}",
            tier="standard",
            demand_gpus=16,
            gpu_hours=16 * 30.0,
            arrival=0.0,
        )
        for i in range(8)
    ]
    trace = FailureTrace.cluster_outage(
        "r0c0", at=4 * 3600.0, repair_seconds=2 * 3600.0
    )
    sim = FleetSimulator(
        fleet,
        jobs,
        ElasticPolicy(),
        SimConfig(horizon_seconds=12 * 3600.0, cost_model=CostModel(), failures=trace),
    )
    res = sim.run()
    assert res.failure_events == 1
    assert res.job_failures > 0
    assert res.lost_work_gpu_seconds > 0
    # repair completed inside the horizon: all capacity healthy again
    assert fleet.capacity() == fleet.total() == 128
    assert all(c.dead_gpus == 0 for c in fleet.clusters())
    # failure-caused restarts were attributed and timed
    assert res.restarts_by_cause.get("failure", 0) > 0
    assert res.ettr_by_tier.get("standard", 0.0) > 0.0


def test_partial_failure_kills_only_overlapping_jobs():
    """A one-node failure takes out the jobs packed onto the failed span,
    not the whole cluster."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 64, gpus_per_node=8)])])
    jobs = [
        Job(
            id=f"j{i}",
            tier="standard",
            demand_gpus=16,
            gpu_hours=16 * 30.0,
            arrival=0.0,
        )
        for i in range(4)
    ]
    trace = FailureTrace.rack_power_loss(
        "r0c0", at=3600.0, nodes=1, gpus_per_node=8, repair_seconds=6 * 3600.0
    )
    sim = FleetSimulator(
        fleet,
        jobs,
        ElasticPolicy(expand_factor=1.0),
        SimConfig(horizon_seconds=2 * 3600.0, cost_model=CostModel(), failures=trace),
    )
    res = sim.run()
    assert res.job_failures == 1  # 8 failed GPUs overlap exactly one 16-GPU job
    assert fleet.clusters()[0].dead_gpus == 8  # repair is past the horizon


# ---------------------------------------------- storm path equivalence
def test_vectorized_equals_scalar_policy_under_failure_storm():
    """The decision-hash equivalence gate must hold with failures,
    drains and forced preemptions in play."""
    sims = {}
    for vec in (True, False):
        sim, wrapper = _storm_sim(vectorized_policy=vec, digest=True)
        res = sim.run()
        sims[vec] = (res, wrapper.digest.hexdigest())
    res_v, dig_v = sims[True]
    res_s, dig_s = sims[False]
    assert res_v.job_failures > 10  # the storm actually stormed
    assert dig_v == dig_s
    assert res_v.utilization == res_s.utilization
    assert res_v.lost_work_gpu_seconds == res_s.lost_work_gpu_seconds
    assert res_v.goodput_fraction == res_s.goodput_fraction
    assert res_v.restarts_by_cause == res_s.restarts_by_cause


def test_legacy_loop_supports_failures():
    """The seed-style per-event loop absorbs the same failure machinery
    (reference oracle for the vectorized loop's failure handling)."""
    fleet = make_fleet()
    jobs = synth_workload(60, fleet.total(), seed=3)
    trace = FailureTrace.cluster_outage("r0c0", at=4 * 3600.0, repair_seconds=3600.0)
    sim = FleetSimulator(
        fleet,
        jobs,
        ElasticPolicy(),
        SimConfig(
            horizon_seconds=24 * 3600.0,
            cost_model=CostModel(),
            failures=trace,
            vectorized=False,
        ),
    )
    res = sim.run()
    assert res.failure_events == 1
    assert res.job_failures > 0
    assert fleet.capacity() == fleet.total()


# --------------------------------------------------- goodput & cadence
def test_goodput_orders_by_tier_under_storm():
    cad = CheckpointCadence(cost_model=CostModel(), failure_model=_storm_model())
    sim, _ = _storm_sim(cadence=cad)
    res = sim.run()
    g = res.goodput_by_tier
    assert set(g) == {"premium", "standard", "basic"}
    assert g["premium"] >= g["standard"] >= g["basic"], g


def test_cadence_strictly_improves_goodput():
    """Young–Daly snapshots bound what a failure can claw back: on the
    same storm, fleet goodput must strictly beat checkpoint-on-preempt-
    only, and lost work must strictly shrink."""
    cad = CheckpointCadence(cost_model=CostModel(), failure_model=_storm_model())
    base_sim, _ = _storm_sim(cadence=None)
    cad_sim, _ = _storm_sim(cadence=cad)
    base, with_cad = base_sim.run(), cad_sim.run()
    assert with_cad.snapshots > 0
    assert with_cad.lost_work_gpu_seconds < base.lost_work_gpu_seconds
    assert with_cad.goodput_fraction > base.goodput_fraction


def test_vectorized_cadence_matches_scalar_sweep_snapshot_for_snapshot():
    """With the JobTable on, the cadence sweep is one masked vector
    update over the columns; with it off, the scalar per-job loop.  On
    the seeded storm both must snapshot the same jobs at the same times
    with the same charges — and the decisions must not shift by a bit."""
    cad = CheckpointCadence(cost_model=CostModel(), failure_model=_storm_model())
    runs = {}
    for job_table in (True, False):
        sim, wrapper = _storm_sim(cadence=cad, digest=True, job_table=job_table)
        res = sim.run()
        per_job = tuple(
            (
                j.id,
                j.snap_progress,
                j.snap_time,
                j.downtime_seconds,
                j.downtime_until,
                j.progress,
                j.failures,
            )
            for j in sim._jobs_list
        )
        runs[job_table] = (
            wrapper.digest.hexdigest(),
            res.snapshots,
            res.lost_work_gpu_seconds,
            res.goodput_fraction,
            res.gpu_seconds_dead,
            per_job,
        )
    assert runs[True][1] > 0  # the cadence actually snapshotted
    assert runs[True] == runs[False]


def test_young_daly_interval_tradeoffs():
    """Cheaper checkpoints and flakier domains shorten the interval."""
    cm = CostModel()
    flaky = CheckpointCadence(
        cost_model=cm, mtti_seconds=3600.0, min_interval_seconds=1.0
    )
    solid = CheckpointCadence(
        cost_model=cm, mtti_seconds=100 * 3600.0, min_interval_seconds=1.0
    )
    small, large = 1 << 30, 64 << 30
    assert flaky.interval_seconds(small, 8) < solid.interval_seconds(small, 8)
    assert flaky.interval_seconds(small, 8) < flaky.interval_seconds(large, 8)
    # clamps hold
    lo = CheckpointCadence(cost_model=cm, mtti_seconds=1.0)
    assert lo.interval_seconds(small, 8) == lo.min_interval_seconds


def test_drain_warning_triggers_proactive_migration():
    """A planned region drain with advance warning: the policy moves the
    running job off the draining cluster BEFORE capacity dies, so the
    drain itself kills nothing."""
    fleet = Fleet(
        [
            Region("r0", [Cluster("r0c0", "r0", 32)]),
            Region("r1", [Cluster("r1c0", "r1", 32)]),
        ]
    )
    j = Job(id="j0", tier="premium", demand_gpus=32, gpu_hours=32 * 20.0, arrival=0.0)
    trace = FailureTrace.region_drain(
        "r0", at=6 * 3600.0, warning_seconds=2 * 3600.0, repair_seconds=12 * 3600.0
    )
    sim = FleetSimulator(
        fleet,
        [j],
        ElasticPolicy(expand_factor=1.0),
        SimConfig(horizon_seconds=10 * 3600.0, cost_model=CostModel(), failures=trace),
    )
    res = sim.run()
    assert j.cluster == "r1c0"  # evacuated to the healthy region
    assert res.migrations == 1
    assert res.job_failures == 0  # the drain found nothing to kill
    assert res.lost_work_gpu_seconds == 0.0


def test_overlapping_failures_do_not_resurrect_capacity_early():
    """A node failure repaired DURING a whole-cluster outage must not
    bring capacity back before the outage's own repair completes."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 64, gpus_per_node=8)])])
    trace = FailureTrace.merge(
        # 8 GPUs die at 1h, repaired at 3h — inside the outage window
        FailureTrace.rack_power_loss(
            "r0c0", at=1 * 3600.0, nodes=1, gpus_per_node=8, repair_seconds=2 * 3600.0
        ),
        FailureTrace.cluster_outage(
            "r0c0", at=2 * 3600.0, repair_seconds=6 * 3600.0
        ),
    )
    j = Job(id="j0", tier="basic", demand_gpus=8, gpu_hours=8 * 100.0, arrival=0.0)
    sim = FleetSimulator(
        fleet,
        [j],
        ElasticPolicy(expand_factor=1.0),
        SimConfig(horizon_seconds=6 * 3600.0, cost_model=CostModel(), failures=trace),
    )
    sim.run()  # horizon ends at 6h: outage (repair 8h) still outstanding
    c = fleet.clusters()[0]
    assert c.capacity() == 0, (
        "node repair during the outage resurrected capacity early"
    )
    assert j.allocated == 0  # nothing may run on a dark cluster


def test_static_policy_respects_failed_capacity():
    """The static gang baseline must also see only healthy capacity, so
    elastic-vs-static comparisons run under failure traces."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 32)])])
    jobs = [
        Job(id=f"j{i}", tier="basic", demand_gpus=32, gpu_hours=32.0, arrival=0.0)
        for i in range(3)
    ]
    trace = FailureTrace.cluster_outage("r0c0", at=3600.0, repair_seconds=2 * 3600.0)
    from repro.scheduler.policy import StaticGangPolicy

    sim = FleetSimulator(
        fleet,
        jobs,
        StaticGangPolicy(),
        SimConfig(horizon_seconds=12 * 3600.0, cost_model=CostModel(), failures=trace),
    )
    res = sim.run()  # validate=True: would assert on over-allocation
    assert res.failure_events == 1


def test_sampling_cap_is_per_level():
    """A high-rate device level must not starve cluster/region sampling."""
    fleet = make_fleet()
    model = FailureModel(
        device_mtbf_seconds=3600.0,  # absurdly flaky devices
        cluster_mtbf_seconds=10 * 24 * 3600.0,
        seed=1,
        max_events=200,
    )
    trace = model.sample(fleet, HORIZON)
    levels = {e.level for e in trace}
    assert "cluster" in levels, "device flakes starved cluster sampling"


def test_unrelated_failure_does_not_cancel_drain_warning():
    """A device flake inside a drain-warning window must not clear the
    draining flag: evacuation continues until the warned deadline."""
    fleet = Fleet(
        [
            Region("r0", [Cluster("r0c0", "r0", 32)]),
            Region("r1", [Cluster("r1c0", "r1", 32)]),
        ]
    )
    j = Job(id="j0", tier="premium", demand_gpus=16, gpu_hours=16 * 20.0, arrival=0.0)
    trace = FailureTrace.merge(
        FailureTrace.region_drain(
            "r0", at=6 * 3600.0, warning_seconds=2 * 3600.0, repair_seconds=12 * 3600.0
        ),
        # flake in the warning window; must not cancel the drain
        FailureTrace.device_flake("r0c0", at=5 * 3600.0, repair_seconds=600.0),
    )
    sim = FleetSimulator(
        fleet,
        [j],
        ElasticPolicy(expand_factor=1.0),
        SimConfig(horizon_seconds=10 * 3600.0, cost_model=CostModel(), failures=trace),
    )
    sim.run()
    assert j.cluster == "r1c0"  # still evacuated despite the flake
    assert j.failures <= 1  # at most the flake itself, never the drain


# ------------------------------------------- real mechanisms (executor)
def test_executor_failure_restores_from_last_checkpoint():
    """Unplanned failure under the REAL mechanisms: drop the runtime with
    no graceful checkpoint; the job restarts from its last durable store
    snapshot and re-earns the lost steps."""
    ex = FleetExecutor(total_slots=2)
    ex.submit(
        ManagedJob(
            id="job", tier="standard", arch="mamba2-130m", world_size=2, total_steps=8
        )
    )
    ex.tick()
    ex.tick()  # a few real steps
    job = ex.jobs["job"]
    assert job.steps_done >= 2
    # force a graceful checkpoint by preempting with a premium arrival
    ex.submit(
        ManagedJob(
            id="prem", tier="premium", arch="mamba2-130m", world_size=2, total_steps=2
        )
    )
    ex.tick()
    assert job.allocated == 0 and job.preemptions == 1
    for _ in range(10):  # premium finishes; job restores and runs on
        ex.tick()
        if job.allocated > 0 and not job.done:
            break
    assert job.allocated > 0 and not job.done
    step_before_failure = job.steps_done
    event = ex.inject_failure("job")
    assert event["rollback_to"] <= step_before_failure
    assert job.runtime is None and job.allocated == 0
    ex.run(max_ticks=40)
    assert job.done and job.steps_done == 8
    events = [e["event"] for e in ex.log]
    assert "failure" in events
    # the post-failure restore really came from the store
    assert events.count("restore") >= 2


def test_executor_failure_before_any_checkpoint_restarts_from_scratch():
    ex = FleetExecutor(total_slots=2)
    ex.submit(
        ManagedJob(
            id="fresh", tier="standard", arch="mamba2-130m", world_size=2, total_steps=4
        )
    )
    ex.tick()
    job = ex.jobs["fresh"]
    assert job.steps_done >= 1
    event = ex.inject_failure("fresh")
    assert event["rollback_to"] == 0 and event["lost_steps"] >= 1
    ex.run(max_ticks=20)
    assert job.done and job.steps_done == 4
    assert any(e["event"] == "restart" for e in ex.log)

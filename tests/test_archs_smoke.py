"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step + prefill/decode on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.models import (decode_step_fn, init_params, model_forward,
                          prefill_fn)
from repro.models.frontend import synth_extra_inputs
from repro.training.state import init_train_state
from repro.training.step import build_train_step

B, S = 2, 64


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update(synth_extra_inputs(cfg, B, key))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["paper-gpt2-1.8b"])
def test_forward_and_decode(arch, rng_key):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, rng_key)
    batch = _batch(cfg, rng_key)

    loss, metrics = jax.jit(lambda p, b: model_forward(p, b, cfg))(
        params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == B * S

    logits, state = jax.jit(lambda p, b: prefill_fn(p, b, cfg))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state2 = jax.jit(
        lambda p, s, t: decode_step_fn(p, s, t, cfg))(params, state, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2)))
    assert int(state2["pos"]) == S + 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch, rng_key):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(total_steps=10, warmup_steps=1)
    state = init_train_state(cfg, tcfg, rng_key)
    step = jax.jit(build_train_step(cfg, tcfg, splice=1))
    batch = _batch(cfg, rng_key)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    p0 = jax.tree_util.tree_leaves(state["params"])[0]
    p1 = jax.tree_util.tree_leaves(new_state["params"])[0]
    assert not np.array_equal(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "granite-moe-3b-a800m":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (40, 8)
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (128, 8)
    if arch in ("zamba2-1.2b",):
        assert cfg.ssm.state_dim == 64
    if arch == "mamba2-130m":
        assert cfg.ssm.state_dim == 128
    assert cfg.source


def test_param_counts_plausible():
    """Analytic parameter counts land near the advertised sizes."""
    approx = {
        "yi-9b": (8.0e9, 10e9),
        "granite-8b": (7.0e9, 9e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        "olmo-1b": (0.9e9, 1.4e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
